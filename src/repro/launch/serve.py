"""Serving launcher, two smokes behind one CLI:

LM mode (default): --arch <id> prefill + decode a batch of prompts with
the layer-stacked KV(/SSM) cache and print tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
        --batch 4 --prompt-len 16 --new-tokens 32

Detection mode: --detect builds a repro.api DetectionSession (training
a quick SVM or loading one with --load), starts session.serve() -- the
micro-batching DetectionService -- streams synthetic frames through it,
and prints per-frame latency, saturation, and service stats.

    PYTHONPATH=src python -m repro.launch.serve --detect [--frames 6]
        [--preset paper] [--load DIR]

`--detect --chaos` replays the standard fault-injection schedule
(serve/faults.py chaos_specs: worker kill, device loss, latency
spikes) through the supervised engine and exits nonzero unless every
submitted frame resolved -- the CLI face of the chaos-smoke CI lane.
`--detect --metrics PATH` streams the service's structured telemetry
(DESIGN.md §15 event schema) to a JSONL file you can `tail -f`.
"""
from __future__ import annotations

from repro import platform  # noqa: F401  (applies REPRO_* before jax init)

import argparse
import sys
import time


def _detect_smoke(args) -> int:
    import numpy as np

    from repro.api import DetectionSession, PipelineConfig, presets
    from repro.core.detector import DetectorConfig
    from repro.core.svm import SVMTrainConfig
    from repro.data.synth_pedestrian import make_scene

    if args.preset:
        cfg = presets(args.preset)
    else:
        cfg = PipelineConfig(
            detector=DetectorConfig(score_threshold=0.5),
            train=SVMTrainConfig(steps=1200, neg_weight=6.0))

    session = None
    if args.load:
        try:
            session = DetectionSession.load(args.load, cfg)
            print(f"loaded SVM params from {args.load}")
        except FileNotFoundError:
            print(f"no checkpoint under {args.load}; training")
    if session is None:
        print(f"training a quick SVM ({cfg.train.steps} steps) ...")
        session = DetectionSession.train(cfg, n_pos=500, n_neg=350)

    opts = {}
    if args.chaos:
        from repro.serve.faults import FaultInjector, chaos_specs
        opts["faults"] = FaultInjector(chaos_specs(), seed=0)
        print("chaos: injecting worker-kill, device-loss, and latency "
              "faults (serve/faults.py chaos_specs)")
    if args.metrics:
        from repro.obs import MetricsConfig
        opts["metrics"] = MetricsConfig(jsonl_path=args.metrics, ring=64)
        print(f"metrics: streaming JSONL events to {args.metrics} "
              f"(tail -f it in another terminal)")
    service = session.serve(**opts).start()
    rng = np.random.default_rng(0)
    frames = [make_scene(rng, 240, 320, n_people=2)[0]
              for _ in range(args.frames)]
    print(f"streaming {args.frames} 320x240 frames through "
          f"session.serve() ...")
    t0 = time.time()
    results = service.detect_frames(frames)
    wall = time.time() - t0
    ms = [r["ms"] for r in results]
    n_sat = sum(bool(r.get("saturated")) for r in results)
    n_box = sum(len(r["detections"]) for r in results)
    n_err = sum("error" in r for r in results)
    if len(ms) > 1:
        print(f"wall          {wall:.2f}s  first={ms[0]:.0f} ms "
              f"(compile), steady={np.mean(ms[1:]):.0f} ms")
    else:
        print(f"wall          {wall:.2f}s")
    print(f"boxes         {n_box} total, {n_sat} frames top-k saturated")
    s = service.stats
    print(f"service stats frames={s['frames']} "
          f"batches={s['frame_batches']} "
          f"occupancy={s['frame_occupancy']:.2f}")
    lat = s["latency_ms"]
    print(f"resilience    p50={lat['p50']:.0f}ms p99={lat['p99']:.0f}ms "
          f"shed={s['deadline_shed']} retries={s['retries']} "
          f"restarts={s['restarts']} "
          f"breaker={s['breaker']['state']} rung={s['degraded_mode']}")
    plat = s["platform"]
    print(f"platform      {plat['backend']} x{plat['device_count']} "
          f"x64={plat['x64']} jax={plat['jax_version']}")
    service.stop()
    if args.metrics:
        from repro.obs import JsonlSink
        events = JsonlSink.read(args.metrics)
        by_kind = {}
        for e in events:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
        kinds = " ".join(f"{k}={n}" for k, n in sorted(by_kind.items()))
        print(f"metrics       {len(events)} events: {kinds}")
    if args.chaos:
        # liveness gate: every future resolved, chaos or not
        resolved = s["frame_answers"] == len(frames)
        print(f"chaos         fired={opts['faults'].fired} "
              f"errors={n_err} all_resolved={resolved}")
        return 0 if resolved else 1
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM serving smoke: arch id (see repro.configs)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--detect", action="store_true",
                    help="detection-service smoke over repro.api "
                         "(DetectionSession.serve)")
    ap.add_argument("--frames", type=int, default=6,
                    help="frames to stream in --detect mode")
    ap.add_argument("--preset", default=None,
                    help="PipelineConfig preset for --detect")
    ap.add_argument("--chaos", action="store_true",
                    help="--detect: run under the standard fault-"
                         "injection schedule (worker kill, device "
                         "loss, latency spikes) and gate on liveness")
    ap.add_argument("--load", metavar="DIR", default=None,
                    help="--detect: restore SVM params from a "
                         "checkpoint dir instead of training")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="--detect: stream service telemetry as JSONL "
                         "events to PATH (DESIGN.md §15 schema)")
    args = ap.parse_args(argv)

    if args.detect:
        return _detect_smoke(args)

    import jax
    import jax.numpy as jnp

    from repro.configs import ARCH_IDS, get_config
    from repro.models.model import init_params
    from repro.serve.engine import generate

    if args.arch not in ARCH_IDS:
        ap.error(f"--arch is required unless --detect "
                 f"(choices: {', '.join(ARCH_IDS)})")

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    enc = None
    if cfg.encoder_layers:
        enc = jnp.zeros((args.batch, cfg.encoder_ctx, cfg.d_model),
                        jnp.float32)
    t0 = time.time()
    out = generate(params, cfg, prompt, max_new_tokens=args.new_tokens,
                   temperature=args.temperature,
                   key=jax.random.PRNGKey(2), enc_input=enc)
    dt = time.time() - t0
    print(f"arch={cfg.name}  out={out.shape}  "
          f"{args.batch*args.new_tokens/dt:,.0f} tok/s (incl. compile)")
    print("sample:", out[0, args.prompt_len:args.prompt_len+16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
