"""Serving launcher: --arch <id> --smoke: prefill + decode a batch of
prompts with the layer-stacked KV(/SSM) cache and print tokens/s.

Usage: PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
          --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.models.model import init_params
from repro.serve.engine import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    enc = None
    if cfg.encoder_layers:
        enc = jnp.zeros((args.batch, cfg.encoder_ctx, cfg.d_model),
                        jnp.float32)
    t0 = time.time()
    out = generate(params, cfg, prompt, max_new_tokens=args.new_tokens,
                   temperature=args.temperature,
                   key=jax.random.PRNGKey(2), enc_input=enc)
    dt = time.time() - t0
    print(f"arch={cfg.name}  out={out.shape}  "
          f"{args.batch*args.new_tokens/dt:,.0f} tok/s (incl. compile)")
    print("sample:", out[0, args.prompt_len:args.prompt_len+16].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
